"""Transfer engine: one ``lax.scan`` = one full SLA-governed transfer.

Composes the network/energy simulator (network_model) with a controller:
either one of the paper's SLA tuners (tuners.py — ME / EEMT / EETT with
Algorithm-3 load control) or a static baseline (baselines.py).

The engine is fully jittable; `vmap(simulate_jit)` sweeps whole parameter
grids in one XLA launch — this is what the benchmark harness and the §Perf
hillclimb use.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import heuristics, network_model, tuners
from .baselines import StaticController
from .types import (CpuProfile, DatasetSpec, NetworkProfile, SLA, SLAPolicy,
                    SimState, TickMetrics, TransferParams, TunerState)


@dataclasses.dataclass
class TransferResult:
    """Post-processed outcome of one simulated transfer."""

    name: str
    time_s: float
    energy_j: float
    avg_tput_mbps: float          # MB/s
    avg_tput_gbps: float          # Gbit/s (paper's unit)
    avg_power_w: float
    completed: bool
    metrics: TickMetrics          # per-tick traces (numpy)

    def row(self) -> str:
        return (f"{self.name},{self.time_s:.1f},{self.energy_j:.0f},"
                f"{self.avg_tput_gbps:.3f},{self.avg_power_w:.1f}")


def _controller_tick(ts: TunerState, sim: SimState, load, profile, cpu, sla,
                     scaling: bool) -> TunerState:
    meas = tuners.Measurement(
        avg_tput=ts.acc_mb / jnp.maximum(ts.acc_s, 1e-6),
        energy_j=ts.acc_j,
        avg_power=ts.acc_j / jnp.maximum(ts.acc_s, 1e-6),
        remaining_mb=jnp.sum(sim.remaining_mb),
        cpu_load=load,
        interval_s=ts.acc_s,
    )
    new = tuners.update(ts, meas, profile, cpu, sla, scaling=scaling)
    z = jnp.zeros((), jnp.float32)
    return new._replace(acc_mb=z, acc_j=z, acc_s=z)


def make_step_fn(profile: NetworkProfile, cpu: CpuProfile, sla: SLA,
                 avg_file_mb, pp, par, *, dt: float, ctrl_every: int,
                 scaling: bool, tuned: bool, static_weights=None):
    """Build the scan step. Static metadata is closed over (hashable)."""

    def step(carry, xs):
        sim, ts = carry
        step_idx, bw_scale = xs

        done = jnp.sum(sim.remaining_mb) <= 0.0
        if static_weights is None:
            cc = heuristics.redistribute_channels(ts.num_ch,
                                                  sim.remaining_mb)
        else:
            # Ismail baseline: channels split by ORIGINAL partition weights
            # (never rebalanced by remaining bytes — the §V-B critique).
            w0 = jnp.asarray(static_weights, jnp.float32)
            active = (sim.remaining_mb > 0.0).astype(jnp.float32)
            cc = w0 * ts.num_ch * active
        params = TransferParams(pp=pp, par=par, cc=cc,
                                cores=ts.cores, freq_idx=ts.freq_idx)

        sim2, out = network_model.step(profile, cpu, sim, params,
                                       avg_file_mb, dt, bw_scale)
        # Freeze the world once the transfer has completed.
        sim2 = jax.tree.map(lambda new, old: jnp.where(done, old, new), sim2, sim)
        sim2 = sim2._replace(t=sim.t + dt)

        live = jnp.logical_not(done)
        ts = ts._replace(
            acc_mb=ts.acc_mb + out.tput_mbps * dt * live,
            acc_j=ts.acc_j + out.power_w * dt * live,
            acc_s=ts.acc_s + dt * live,
        )

        if tuned:
            is_ctrl = jnp.logical_and((step_idx % ctrl_every) == ctrl_every - 1,
                                      live)
            ts_new = _controller_tick(ts, sim2, out.cpu_load, profile, cpu,
                                      sla, scaling)
            ts = jax.tree.map(lambda n, o: jnp.where(is_ctrl, n, o), ts_new, ts)

        _, f = _op(cpu, ts)
        metrics = TickMetrics(
            tput_mbps=out.tput_mbps * live, power_w=out.power_w * live,
            cpu_load=out.cpu_load, num_ch=out.num_ch,
            cores=ts.cores, freq_ghz=f, done=done,
        )
        return (sim2, ts), metrics

    return step


def _op(cpu, ts):
    from . import energy_model
    return energy_model.operating_point(cpu, ts.cores, ts.freq_idx)


@functools.partial(jax.jit, static_argnames=(
    "profile", "cpu", "sla", "n_steps", "dt", "ctrl_every", "scaling",
    "tuned", "pp_t", "par_t", "files_t", "totals_t", "static_weights"))
def _simulate_jit(num_ch0, cores0, freq0, *, profile, cpu, sla, n_steps, dt,
                  ctrl_every, scaling, tuned, pp_t, par_t, files_t, totals_t,
                  bw_schedule, static_weights=None):
    pp = jnp.asarray(pp_t, jnp.float32)
    par = jnp.asarray(par_t, jnp.float32)
    avg_file = jnp.asarray(files_t, jnp.float32)
    totals = jnp.asarray(totals_t, jnp.float32)

    sim0 = network_model.init_state(totals, profile)
    ts0 = tuners.init_tuner_state(num_ch0, cores0, freq0)

    step = make_step_fn(profile, cpu, sla, avg_file, pp, par, dt=dt,
                        ctrl_every=ctrl_every, scaling=scaling, tuned=tuned,
                        static_weights=static_weights)
    xs = (jnp.arange(n_steps, dtype=jnp.int32), bw_schedule)
    (sim, ts), metrics = jax.lax.scan(step, (sim0, ts0), xs)
    return sim, ts, metrics


def simulate(
    profile: NetworkProfile,
    cpu: CpuProfile,
    specs,
    controller,
    sla: Optional[SLA] = None,
    *,
    total_s: float = 3600.0,
    dt: float = 0.1,
    scaling: bool = True,
    bw_schedule: Optional[np.ndarray] = None,
    name: Optional[str] = None,
) -> TransferResult:
    """Run one transfer to completion (or ``total_s`` timeout).

    ``controller`` is either an ``SLA`` (run the matching paper tuner) or a
    ``StaticController`` baseline.
    """
    n_steps = int(round(total_s / dt))

    if isinstance(controller, StaticController):
        params, chunked = controller.params, tuple(specs)
        sla = sla or SLA()
        tuned = False
        scaling_eff = False
        num_ch0 = float(jnp.sum(params.cc))
        cores0, freq0 = int(params.cores), int(params.freq_idx)
        pp_t = tuple(float(x) for x in np.asarray(params.pp))
        par_t = tuple(float(x) for x in np.asarray(params.par))
        label = controller.name
    else:
        assert isinstance(controller, SLA)
        sla = controller
        params, chunked = heuristics.initialize(specs, profile, cpu, sla)
        tuned = True
        scaling_eff = scaling
        num_ch0 = float(jnp.sum(params.cc))
        if sla.policy == SLAPolicy.ISMAIL_TARGET:
            # baseline semantics: 1 channel, OS-default CPU, no scaling
            num_ch0 = 1.0
            scaling_eff = False
            cores0, freq0 = cpu.num_cores, len(cpu.freq_levels_ghz) - 1
        elif scaling:
            cores0, freq0 = int(params.cores), int(params.freq_idx)
        else:
            # Fig. 4 ablation: load-control module removed -> the host runs
            # at OS defaults (performance governor: all cores, max freq).
            cores0, freq0 = cpu.num_cores, len(cpu.freq_levels_ghz) - 1
        pp_t = tuple(float(x) for x in np.asarray(params.pp))
        par_t = tuple(float(x) for x in np.asarray(params.par))
        label = {0: "ME", 1: "EEMT", 2: "EETT",
                 3: "ismail-target"}[int(sla.policy)]
        if not scaling and sla.policy != SLAPolicy.ISMAIL_TARGET:
            label += "-noscale"

    files_t = tuple(s.avg_file_mb for s in chunked)
    totals_t = tuple(s.total_mb for s in chunked)
    if isinstance(controller, SLA) and \
            sla.policy == SLAPolicy.ISMAIL_TARGET:
        tot = sum(totals_t)
        static_weights = tuple(t / tot for t in totals_t)
    else:
        static_weights = None
    ctrl_every = max(int(round(sla.timeout_s / dt)), 1)

    if bw_schedule is None:
        bw = jnp.ones((n_steps,), jnp.float32)
    else:
        bw = jnp.asarray(bw_schedule, jnp.float32)
        assert bw.shape == (n_steps,)

    sim, ts, metrics = _simulate_jit(
        jnp.asarray(num_ch0, jnp.float32), jnp.asarray(cores0, jnp.int32),
        jnp.asarray(freq0, jnp.int32), profile=profile, cpu=cpu, sla=sla,
        n_steps=n_steps, dt=dt, ctrl_every=ctrl_every, scaling=scaling_eff,
        tuned=tuned, pp_t=pp_t, par_t=par_t, files_t=files_t,
        totals_t=totals_t, bw_schedule=bw, static_weights=static_weights)

    m = jax.tree.map(np.asarray, metrics)
    done = m.done
    completed = bool(done[-1])
    if completed:
        t_done = float(dt * int(np.argmax(done)))
    else:
        t_done = float(total_s)
    energy = float(sim.energy_j)
    total_mb = float(sum(totals_t))
    moved = float(sim.bytes_moved)
    avg_tput = moved / max(t_done, 1e-9)
    avg_power = energy / max(t_done, 1e-9)

    return TransferResult(
        name=name or label,
        time_s=t_done,
        energy_j=energy,
        avg_tput_mbps=avg_tput,
        avg_tput_gbps=avg_tput * 8.0 / 1000.0,
        avg_power_w=avg_power,
        completed=completed,
        metrics=m,
    )
